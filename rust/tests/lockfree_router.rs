//! Multi-threaded stress tests for the lock-free two-choices hot path:
//! the sticky table must be first-writer-wins under racing first
//! sightings (one global owner per key, never a split), and readers
//! racing writers + redistributions must never observe a torn owner —
//! every routed destination is a valid node id at every instant.
//!
//! These tests pin the PR's headline invariant: the steady-state route
//! read path (sticky-table HITS) takes no RwLock, so heavy reader
//! concurrency cannot serialize — and, more importantly here, cannot
//! trade away correctness for that speed.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};
use std::thread;

use dpa::hash::{RouterHandle, TwoChoicesRouter};

const NODES: usize = 4;

/// A deterministic spread of distinct key hashes (odd-constant multiply:
/// a bijection on u32, so `n` inputs give `n` distinct hashes).
fn hashes(n: u32, salt: u32) -> Vec<u32> {
    (0..n).map(|i| (i ^ salt).wrapping_mul(0x9E37_79B9) ^ salt).collect()
}

fn handle() -> RouterHandle {
    RouterHandle::new(Box::new(TwoChoicesRouter::new(NODES)))
}

#[test]
fn concurrent_first_sighting_is_first_writer_wins() {
    let h = handle();
    let keys = Arc::new(hashes(20_000, 0xA5A5));
    let writers = 8;
    let barrier = Arc::new(Barrier::new(writers));

    let mut joins = Vec::new();
    for w in 0..writers {
        let h = h.clone();
        let keys = Arc::clone(&keys);
        let barrier = Arc::clone(&barrier);
        joins.push(thread::spawn(move || {
            // every writer first-sights every key, each starting at a
            // different offset so the race covers the whole key set
            let start = w * keys.len() / writers;
            let mut seen: Vec<(u32, usize)> = Vec::with_capacity(keys.len());
            barrier.wait();
            for i in 0..keys.len() {
                let k = keys[(start + i) % keys.len()];
                seen.push((k, h.route_hash(k)));
            }
            seen
        }));
    }

    let mut owner: HashMap<u32, usize> = HashMap::with_capacity(keys.len());
    for j in joins {
        for (k, dest) in j.join().unwrap() {
            assert!(dest < NODES, "torn read: key {k:#x} routed to {dest}");
            // first-writer-wins: whichever insert won the CAS, every
            // thread (including the losers) must have adopted it
            match owner.insert(k, dest) {
                None => {}
                Some(prev) => assert_eq!(
                    prev, dest,
                    "key {k:#x} split across owners {prev} and {dest}"
                ),
            }
        }
    }
    assert_eq!(owner.len(), keys.len());
    // the winning assignments stuck: a quiesced re-route agrees
    for (&k, &dest) in &owner {
        assert_eq!(h.route_hash(k), dest, "key {k:#x} moved after the race");
    }
}

#[test]
fn readers_never_see_torn_owners_under_redistribution() {
    let h = handle();
    // skew the load signal so redistribute always has work to consider
    for n in 0..NODES {
        h.loads().set(n, ((n as u64) + 1) * 50);
    }
    let hot = Arc::new(hashes(2_000, 0x1234));
    for &k in hot.iter() {
        h.route_hash(k); // pre-sight, so readers start on table HITS
    }

    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();

    // readers: hammer the sticky HIT path (and the RouterCache batch
    // path) while epochs churn underneath them
    for r in 0..4 {
        let h = h.clone();
        let hot = Arc::clone(&hot);
        let stop = Arc::clone(&stop);
        joins.push(thread::spawn(move || {
            let mut cache = h.cache();
            let mut dests = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                if r % 2 == 0 {
                    for &k in hot.iter() {
                        let dest = h.route_hash(k);
                        assert!(dest < NODES, "torn read: {k:#x} -> {dest}");
                    }
                } else {
                    cache.route_batch(&hot, &mut dests);
                    for (&k, &dest) in hot.iter().zip(&dests) {
                        assert!(dest < NODES, "torn batch read: {k:#x} -> {dest}");
                    }
                }
            }
        }));
    }

    // writers: keep first-sighting fresh keys so table inserts (and
    // segment growth) race the reads
    for w in 0..2u32 {
        let h = h.clone();
        let stop = Arc::clone(&stop);
        joins.push(thread::spawn(move || {
            let mut round = 0u32;
            while !stop.load(Ordering::Relaxed) {
                for k in hashes(500, 0x8000_0000 | (w << 24) | round) {
                    let dest = h.route_hash(k);
                    assert!(dest < NODES, "torn write-path read: {k:#x} -> {dest}");
                }
                round = round.wrapping_add(1);
            }
        }));
    }

    // the churn: redistributions bump the epoch and rewrite sticky
    // entries while everyone above is routing
    let mut moved = 0u64;
    for i in 0..300 {
        let delta = h.redistribute(i % NODES);
        moved += delta.keys_reassigned;
        thread::yield_now();
    }
    stop.store(true, Ordering::Relaxed);
    for j in joins {
        j.join().unwrap();
    }

    // quiesced: every hot key still has exactly one stable, valid owner
    for &k in hot.iter() {
        let dest = h.route_hash(k);
        assert!(dest < NODES);
        assert_eq!(h.route_hash(k), dest, "key {k:#x} unstable after quiesce");
    }
    // not an assertion on `moved` being nonzero (gain guards may veto
    // every move under some interleavings), but keep the count observable
    let _ = moved;
}
