//! Bounded loom models for the lock-free hot path — exhaustive
//! interleaving + memory-ordering exploration of the invariants the
//! stress suite (`lockfree_router.rs`) can only sample:
//!
//! * `AssignTable` first-writer-wins under racing inserters, including
//!   colliding keys that share one probe window;
//! * no reader ever observes a torn `(hash, owner)` slot — neither
//!   against a racing insert (CAS path) nor against the non-CAS
//!   `rewrite` write-back (`hash/router.rs`, serialized by the
//!   membership write lock: the model proves the plain `Release` store
//!   safe under that contract, so it does not need to become a CAS);
//! * `RouterHandle` snapshot-before-epoch publication: a reader that
//!   observes epoch N must find N's router already published, never
//!   N−1's;
//! * `DataQueue` push/push_batch/pop never lose, duplicate or reorder
//!   items, and the §7 priority lane always pops first;
//! * `Histogram`'s relaxed counters lose no increments;
//! * `ShutdownMonitor::drained` can never report true with a record in
//!   flight (the load-order comment in `actor/mod.rs`, made a theorem).
//!
//! Run with `RUSTFLAGS="--cfg loom" cargo test --release --test
//! loom_models`. CI bounds the search with `LOOM_MAX_PREEMPTIONS=3`
//! (sound for the 2–3 thread models here per loom's guidance); the
//! nightly sweep and the `workflow_dispatch` `exhaustive` input run
//! unbounded. Models create every structure *inside* `loom::model` and
//! keep key counts far below one `AssignTable` probe window, so the
//! non-loom `OnceCell` segment-growth latch is never exercised (see
//! `src/sync/mod.rs`).
#![cfg(loom)]

use std::collections::HashMap;
use std::time::Duration;

use loom::thread;

use dpa::hash::{AssignTable, Loads, RouteDelta, RouteSnapshot, Router, RouterHandle,
    SnapshotState};
use dpa::metrics::Histogram;
use dpa::queue::DataQueue;
use dpa::sync::Arc;

/// Two distinct key hashes that land on the same first-segment probe
/// start (the fib multiply-shift over 1024 slots, mirrored from
/// `Segment::start` and re-asserted against `AssignTable::probe_start`
/// inside each model that uses the pair).
fn colliding_pair() -> (u32, u32) {
    let start = |h: u32| {
        ((h as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize & (1024 - 1)
    };
    let mut seen: HashMap<usize, u32> = HashMap::new();
    for h in 1u32..=100_000 {
        if let Some(&prev) = seen.get(&start(h)) {
            return (prev, h);
        }
        seen.insert(start(h), h);
    }
    unreachable!("1024 slots must collide within 100k hashes");
}

#[test]
fn assign_table_first_writer_wins() {
    loom::model(|| {
        let t = Arc::new(AssignTable::new());
        let (ta, tb) = (t.clone(), t.clone());
        let a = thread::spawn(move || ta.insert_or_get(0xDEAD_BEEF, 1));
        let b = thread::spawn(move || tb.insert_or_get(0xDEAD_BEEF, 2));
        let (ra, rb) = (a.join().unwrap(), b.join().unwrap());
        // whichever CAS won, BOTH inserters adopted the same owner …
        assert_eq!(ra, rb, "key split across owners {ra} and {rb}");
        // … and that owner is what every later route reads
        assert_eq!(t.get(0xDEAD_BEEF), Some(ra));
    });
}

#[test]
fn assign_table_colliding_keys_never_cross() {
    let (h1, h2) = colliding_pair();
    loom::model(move || {
        let t = Arc::new(AssignTable::new());
        assert_eq!(t.probe_start(h1), t.probe_start(h2), "pair must collide");
        let t1 = t.clone();
        let a = thread::spawn(move || t1.insert_or_get(h1, 1));
        // racing inserter of a *different* key in the same probe window:
        // losing the CAS on h1's slot must re-examine and walk on, never
        // adopt h1's entry
        let got2 = t.insert_or_get(h2, 2);
        assert_eq!(a.join().unwrap(), 1);
        assert_eq!(got2, 2);
        assert_eq!(t.get(h1), Some(1));
        assert_eq!(t.get(h2), Some(2));
    });
}

#[test]
fn assign_table_insert_is_never_torn() {
    loom::model(|| {
        let t = Arc::new(AssignTable::new());
        let t1 = t.clone();
        let w = thread::spawn(move || {
            t1.insert_or_get(0x1234_5678, 3);
        });
        // racing reader: the key is absent or fully written — a torn
        // word would decode as hash-match with a garbage owner
        match t.get(0x1234_5678) {
            None => {}
            Some(owner) => assert_eq!(owner, 3, "torn slot observed"),
        }
        w.join().unwrap();
        assert_eq!(t.get(0x1234_5678), Some(3));
    });
}

#[test]
fn assign_table_rewrite_is_never_torn() {
    let (h1, h2) = colliding_pair();
    loom::model(move || {
        let t = Arc::new(AssignTable::new());
        t.insert_or_get(h1, 1);
        // one rewriter (callers serialize through the membership write
        // lock — modeled by using a single rewriter thread), one racing
        // inserter in the same probe window, one racing reader (main)
        let t1 = t.clone();
        let rw = thread::spawn(move || t1.rewrite(h1, 7));
        let t2 = t.clone();
        let ins = thread::spawn(move || t2.insert_or_get(h2, 2));
        let seen = t.get(h1);
        assert!(
            seen == Some(1) || seen == Some(7),
            "torn rewrite observed: {seen:?}"
        );
        rw.join().unwrap();
        ins.join().unwrap();
        assert_eq!(t.get(h1), Some(7), "rewrite lost");
        assert_eq!(t.get(h2), Some(2), "colliding insert lost");
    });
}

/// Minimal `Router` whose `redistribute` only bumps its epoch — isolates
/// the model to `RouterHandle`'s publication machinery.
#[derive(Clone)]
struct BumpRouter {
    epoch: u64,
}

impl Router for BumpRouter {
    fn name(&self) -> &'static str {
        "bump"
    }

    fn nodes(&self) -> usize {
        1
    }

    fn epoch(&self) -> u64 {
        self.epoch
    }

    fn route(&self, _hash: u32, _loads: &Loads) -> usize {
        0
    }

    fn redistribute(&mut self, _target: usize, _loads: &Loads) -> RouteDelta {
        self.epoch += 1;
        RouteDelta { changed: true, ..RouteDelta::default() }
    }

    fn add_node(&mut self, _id: usize) -> RouteDelta {
        RouteDelta::unchanged()
    }

    fn retire_node(&mut self, _id: usize, _loads: &Loads) -> RouteDelta {
        RouteDelta::unchanged()
    }

    fn snapshot(&self, _loads: &Loads) -> RouteSnapshot {
        RouteSnapshot {
            router: "bump",
            epoch: self.epoch,
            nodes: 1,
            state: SnapshotState::TokenRing { tokens: Vec::new() },
        }
    }

    fn clone_router(&self) -> Box<dyn Router> {
        Box::new(self.clone())
    }
}

#[test]
fn handle_publishes_snapshot_before_epoch() {
    loom::model(|| {
        let h = RouterHandle::new(Box::new(BumpRouter { epoch: 1 }));
        let writer = h.clone();
        let w = thread::spawn(move || {
            writer.redistribute(0);
        });
        // the invariant every RouterCache staleness check leans on: a
        // reader that observes epoch N finds N's router (or newer)
        // already published — never the previous epoch's snapshot
        let e = h.epoch();
        let r = h.published_router();
        assert!(
            r.epoch() >= e,
            "epoch {e} visible before its router (published router at {})",
            r.epoch()
        );
        w.join().unwrap();
        assert_eq!(h.epoch(), 2);
        assert_eq!(h.published_router().epoch(), 2);
    });
}

#[test]
fn queue_conserves_and_keeps_data_fifo_under_race() {
    loom::model(|| {
        let q = Arc::new(DataQueue::new(8));
        let q1 = q.clone();
        let p = thread::spawn(move || {
            q1.push_batch(vec![1u32, 2]);
            q1.push_priority(9);
        });
        // racing consumer on the non-blocking path
        let mut got = Vec::new();
        for _ in 0..2 {
            if let Some(v) = q.try_pop() {
                got.push(v);
            }
        }
        p.join().unwrap();
        got.extend(q.drain());
        let mut sorted = got.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![1, 2, 9], "lost or duplicated items: {got:?}");
        // data-lane FIFO survives the race: 1 always pops before 2
        let i1 = got.iter().position(|&v| v == 1).unwrap();
        let i2 = got.iter().position(|&v| v == 2).unwrap();
        assert!(i1 < i2, "data lane reordered: {got:?}");
        assert_eq!(q.len(), 0, "len mirror out of sync after drain");
    });
}

#[test]
fn queue_priority_lane_pops_first_whatever_the_race() {
    loom::model(|| {
        let q = Arc::new(DataQueue::new(8));
        let q1 = q.clone();
        let p = thread::spawn(move || q1.push(5u32));
        // a §7 state transfer racing a data producer
        q.push_priority(9);
        p.join().unwrap();
        // both landed; whichever lock acquisition won, state pops first
        let got = q.pop_batch(2, Duration::from_millis(0));
        assert_eq!(got, vec![9, 5], "priority lane did not pop first");
    });
}

#[test]
fn histogram_relaxed_counters_lose_nothing() {
    loom::model(|| {
        let h = Arc::new(Histogram::new());
        let h1 = h.clone();
        let a = thread::spawn(move || h1.record(3));
        h.record(40);
        a.join().unwrap();
        // both relaxed fetch_adds landed (bucket-sum exactness across
        // disjoint value sets is pinned by the props.rs property test)
        assert_eq!(h.count(), 2);
    });
}

#[test]
fn shutdown_drained_is_never_true_with_records_in_flight() {
    use dpa::actor::ShutdownMonitor;
    loom::model(|| {
        let m = Arc::new(ShutdownMonitor::new(1));
        let m1 = m.clone();
        let t = thread::spawn(move || {
            m1.produced(1);
            m1.mapper_done();
        });
        // nothing is ever consumed in this model, so drained() must be
        // false under EVERY interleaving of its two loads with the
        // producer — this fails if the mappers-then-in-flight load order
        // in ShutdownMonitor::drained is flipped
        assert!(!m.drained(), "drained() true with a record in flight");
        t.join().unwrap();
        assert!(!m.drained());
        m.consumed();
        assert!(m.drained());
    });
}
