//! End-to-end pipeline correctness across drivers, strategies, executors
//! and consistency modes: the result must always equal the serial oracle,
//! every mapped record must be reduced exactly once, and the system must
//! terminate.

// experiment configs override one default knob at a time (see lib.rs)
#![allow(clippy::field_reassign_with_default)]

use std::collections::HashMap;

use dpa::balancer::state_forward::ConsistencyMode;
use dpa::exec::builtin::TopK;
use dpa::hash::Strategy;
use dpa::metrics::RunReport;
use dpa::pipeline::{DriverKind, ExecutorKind, Pipeline, PipelineConfig};
use dpa::workload::{corpus, generators, paperwl};

fn wordcount_oracle(items: &[String]) -> Vec<(String, i64)> {
    let mut m: HashMap<String, i64> = HashMap::new();
    for i in items {
        *m.entry(i.clone()).or_insert(0) += 1;
    }
    let mut v: Vec<(String, i64)> = m.into_iter().collect();
    v.sort();
    v
}

fn check(report: &RunReport, items: &[String]) {
    report.check_conservation().expect("conservation");
    assert_eq!(report.result, wordcount_oracle(items), "result == oracle");
}

#[test]
fn every_paper_workload_correct_under_every_strategy_sim() {
    for w in paperwl::all() {
        for strategy in Strategy::all() {
            for seed in [0u64, 1, 2] {
                let mut cfg = PipelineConfig::default();
                cfg.strategy = strategy;
                cfg.initial_tokens = Some(strategy.initial_tokens(8));
                cfg.seed = seed;
                cfg.max_rounds = 2;
                let r = Pipeline::wordcount(cfg).run(w.items.clone()).unwrap();
                check(&r, &w.items);
            }
        }
    }
}

#[test]
fn threads_driver_correct_under_lb() {
    for strategy in [Strategy::Halving, Strategy::Doubling] {
        let w = paperwl::wl4();
        let mut cfg = PipelineConfig::default();
        cfg.driver = DriverKind::Threads;
        cfg.strategy = strategy;
        cfg.initial_tokens = Some(strategy.initial_tokens(8));
        cfg.reduce_delay_us = 300;
        let r = Pipeline::wordcount(cfg).run(w.items.clone()).unwrap();
        check(&r, &w.items);
        assert!(r.wall > std::time::Duration::ZERO);
    }
}

#[test]
fn large_zipf_stream_sim() {
    let w = generators::zipf(5000, 300, 1.1, 3);
    let mut cfg = PipelineConfig::default();
    cfg.strategy = Strategy::Doubling;
    cfg.initial_tokens = Some(1);
    cfg.max_rounds = 3;
    let r = Pipeline::wordcount(cfg).run(w.items.clone()).unwrap();
    check(&r, &w.items);
    assert_eq!(r.total_processed(), 5000);
}

#[test]
fn corpus_pipeline_tokenizing_mapper() {
    // lines in, words counted: map emits multiple records per item
    let text = corpus::generate(2000, 1.0, 5);
    let lines: Vec<String> = text.lines().map(str::to_string).collect();
    let words: Vec<String> = text.split_whitespace().map(str::to_string).collect();
    let mut cfg = PipelineConfig::default();
    cfg.strategy = Strategy::Doubling;
    cfg.initial_tokens = Some(1);
    let p = Pipeline::builtin(cfg, ExecutorKind::TokenizedWordCount);
    let r = p.run(lines).unwrap();
    assert_eq!(r.result, wordcount_oracle(&words));
    assert_eq!(r.total_processed(), 2000);
}

#[test]
fn keyed_sum_executor() {
    let items: Vec<String> = (0..100).map(|i| format!("k{}:{}", i % 5, i)).collect();
    let cfg = PipelineConfig::default();
    let r = Pipeline::builtin(cfg, ExecutorKind::KeyedSum).run(items).unwrap();
    // sum over i of each residue class
    let mut expect: Vec<(String, i64)> = (0..5)
        .map(|k| {
            let s: i64 = (0..100).filter(|i| i % 5 == k).sum();
            (format!("k{k}"), s)
        })
        .collect();
    expect.sort();
    assert_eq!(r.result, expect);
}

#[test]
fn distinct_executor() {
    let items: Vec<String> = (0..100).map(|i| format!("d{}", i % 7)).collect();
    let cfg = PipelineConfig::default();
    let r = Pipeline::builtin(cfg, ExecutorKind::Distinct).run(items).unwrap();
    assert_eq!(r.result.len(), 7);
    assert!(r.result.iter().all(|(_, v)| *v == 1));
}

#[test]
fn topk_post_selection() {
    let mut items = vec!["hot".to_string(); 50];
    items.extend((0..50).map(|i| format!("cold{i}")));
    let cfg = PipelineConfig::default();
    let r = Pipeline::builtin(cfg, ExecutorKind::TopK(3)).run(items).unwrap();
    let top = TopK::top(&r.result, 3);
    assert_eq!(top[0], ("hot".to_string(), 50));
    assert_eq!(top.len(), 3);
}

#[test]
fn state_forwarding_equals_merge_at_end() {
    // the two consistency modes must produce identical results
    for w in [paperwl::wl1(), paperwl::wl4()] {
        let mut base = PipelineConfig::default();
        base.strategy = Strategy::Doubling;
        base.initial_tokens = Some(1);
        base.max_rounds = 2;

        let mut sf = base.clone();
        sf.mode = ConsistencyMode::StateForward;

        let a = Pipeline::wordcount(base).run(w.items.clone()).unwrap();
        let b = Pipeline::wordcount(sf).run(w.items.clone()).unwrap();
        assert_eq!(a.result, b.result, "{}", w.name);
        check(&b, &w.items);
    }
}

#[test]
fn sim_runs_are_deterministic_threads_are_correct_anyway() {
    let w = paperwl::wl4();
    let mut cfg = PipelineConfig::default();
    cfg.strategy = Strategy::Halving;
    let p = Pipeline::wordcount(cfg);
    let a = p.run(w.items.clone()).unwrap();
    let b = p.run(w.items.clone()).unwrap();
    assert_eq!(a.processed, b.processed);
    assert_eq!(a.virtual_end, b.virtual_end);
    assert_eq!(
        a.lb_events.iter().map(|e| (e.at, e.target)).collect::<Vec<_>>(),
        b.lb_events.iter().map(|e| (e.at, e.target)).collect::<Vec<_>>()
    );
}

#[test]
fn seed_sweep_reports_variance() {
    let w = paperwl::wl4();
    let mut cfg = PipelineConfig::default();
    cfg.strategy = Strategy::Doubling;
    cfg.initial_tokens = Some(1);
    let p = Pipeline::wordcount(cfg);
    let reports = p.run_seeds(&w.items, &[0, 1, 2]).unwrap();
    assert_eq!(reports.len(), 3);
    for r in &reports {
        check(r, &w.items);
    }
}

#[test]
fn single_mapper_single_reducer_degenerate() {
    let mut cfg = PipelineConfig::default();
    cfg.mappers = 1;
    cfg.reducers = 1;
    let items: Vec<String> = (0..50).map(|i| format!("x{i}")).collect();
    let r = Pipeline::wordcount(cfg).run(items.clone()).unwrap();
    check(&r, &items);
    assert_eq!(r.skew(), 0.0, "one reducer cannot be skewed");
}

#[test]
fn many_reducers_sim() {
    let mut cfg = PipelineConfig::default();
    cfg.reducers = 16;
    cfg.mappers = 8;
    cfg.strategy = Strategy::Doubling;
    cfg.initial_tokens = Some(1);
    let w = generators::zipf(2000, 100, 1.3, 11);
    let r = Pipeline::wordcount(cfg).run(w.items.clone()).unwrap();
    check(&r, &w.items);
    assert_eq!(r.processed.len(), 16);
}
