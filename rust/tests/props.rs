//! Property-based tests (via the in-crate testkit) over the system's core
//! invariants: ring behaviour under arbitrary redistribution sequences,
//! skew-metric bounds, policy trigger semantics, queue conservation, and
//! whole-pipeline correctness on random workloads.

// experiment configs override one default knob at a time (see lib.rs)
#![allow(clippy::field_reassign_with_default)]

use dpa::balancer::policy::{LbPolicy, ThresholdPolicy};
use dpa::balancer::state_forward::ConsistencyMode;
use dpa::hash::{murmur3_x86_32, Ring, RingOp, RouterHandle, Strategy, StrategySpec};
use dpa::metrics::skew;
use dpa::pipeline::{Pipeline, PipelineConfig};
use dpa::prop_assert;
use dpa::testkit::{forall, Gen};
use dpa::util::ceil_div;

/// Apply a random sequence of redistributions/node-adds to a ring.
fn random_ring(g: &mut Gen) -> Ring {
    let nodes = g.usize_in(2, 8);
    let tokens = 1 << g.usize_in(0, 4);
    let mut ring = Ring::new(nodes, tokens as u32);
    let ops = g.usize_in(0, 12);
    for _ in 0..ops {
        let node = g.usize_in(0, ring.nodes() - 1);
        match g.usize_in(0, 9) {
            0..=4 => {
                ring.halve(node);
            }
            5..=8 => {
                ring.double_others(node);
            }
            _ => {
                if ring.nodes() < 12 {
                    ring.add_node(1 + g.usize_in(0, 7) as u32);
                }
            }
        }
    }
    ring
}

#[test]
fn prop_ring_lookup_matches_linear_oracle() {
    forall("ring lookup == linear scan", 60, |g| {
        let ring = random_ring(g);
        for _ in 0..50 {
            let h = g.u32();
            prop_assert!(
                ring.lookup_hash(h) == ring.lookup_hash_linear(h),
                "hash {h:#x} on ring with {} tokens",
                ring.total_tokens()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_every_key_maps_to_live_node() {
    forall("lookup returns a live node", 60, |g| {
        let ring = random_ring(g);
        let nodes = ring.nodes();
        for _ in 0..30 {
            let key = g.string(24);
            let owner = ring.lookup(key.as_bytes());
            prop_assert!(owner < nodes, "owner {owner} of '{key}' out of range");
        }
        Ok(())
    });
}

#[test]
fn prop_halving_never_moves_other_nodes_keys() {
    forall("halving only sheds the target's keys", 40, |g| {
        let mut ring = random_ring(g);
        let keys: Vec<String> = (0..60).map(|_| g.string(16)).collect();
        let before: Vec<usize> = keys.iter().map(|k| ring.lookup(k.as_bytes())).collect();
        let target = g.usize_in(0, ring.nodes() - 1);
        if !ring.halve(target) {
            return Ok(()); // single token, nothing changed
        }
        for (k, &owner) in keys.iter().zip(&before) {
            if owner != target {
                prop_assert!(
                    ring.lookup(k.as_bytes()) == owner,
                    "'{k}' moved off untouched node {owner}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_doubling_preserves_target_tokens() {
    forall("doubling leaves the target alone", 40, |g| {
        let mut ring = random_ring(g);
        let target = g.usize_in(0, ring.nodes() - 1);
        let before: Vec<u32> = (0..ring.nodes()).map(|n| ring.tokens_of(n)).collect();
        ring.double_others(target);
        prop_assert!(
            ring.tokens_of(target) == before[target],
            "target token count changed"
        );
        for n in 0..ring.nodes() {
            if n != target {
                let expect = (before[n] * 2).min(dpa::hash::ring::MAX_TOKENS_PER_NODE);
                prop_assert!(
                    ring.tokens_of(n) == expect,
                    "node {n}: {} != {expect}",
                    ring.tokens_of(n)
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_arc_fractions_always_sum_to_one() {
    forall("arc fractions partition the ring", 40, |g| {
        let ring = random_ring(g);
        let total: f64 = (0..ring.nodes()).map(|n| ring.arc_fraction(n)).sum();
        prop_assert!((total - 1.0).abs() < 1e-9, "sum {total}");
        Ok(())
    });
}

#[test]
fn prop_murmur3_incremental_byte_change_changes_hash() {
    // not a cryptographic property — just detects packing/indexing bugs
    // where some byte positions are ignored
    forall("every byte position affects the hash", 40, |g| {
        let mut bytes = g.bytes(31);
        bytes.push(g.u32() as u8);
        let h0 = murmur3_x86_32(&bytes);
        let pos = g.usize_in(0, bytes.len() - 1);
        let old = bytes[pos];
        bytes[pos] = old.wrapping_add(1 + (g.u32() % 255) as u8);
        if bytes[pos] == old {
            return Ok(());
        }
        prop_assert!(
            murmur3_x86_32(&bytes) != h0,
            "flipping byte {pos} of {} did not change the hash",
            bytes.len()
        );
        Ok(())
    });
}

#[test]
fn prop_skew_bounds_and_extremes() {
    forall("S in [0,1], 0 iff uniform-ish, 1 iff single", 100, |g| {
        let r = g.usize_in(2, 12);
        let loads: Vec<u64> = (0..r).map(|_| g.usize_in(0, 200) as u64).collect();
        let s = skew(&loads);
        prop_assert!((0.0..=1.0).contains(&s), "S = {s} for {loads:?}");
        let m: u64 = loads.iter().sum();
        if m > 1 {
            // all on one reducer -> 1
            let mut single = vec![0u64; r];
            single[0] = m;
            prop_assert!(skew(&single) == 1.0, "single-reducer S != 1");
            // perfectly uniform and divisible -> 0
            if m % r as u64 == 0 {
                let uniform = vec![m / r as u64; r];
                prop_assert!(skew(&uniform) == 0.0, "uniform S != 0");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_policy_fires_iff_eq1() {
    forall("ThresholdPolicy == literal Eq.1", 100, |g| {
        let tau = g.f64() * 2.0;
        let policy = ThresholdPolicy::new(tau, 1);
        let n = g.usize_in(2, 8);
        let qlens: Vec<usize> = (0..n).map(|_| g.usize_in(0, 100)).collect();
        // literal Eq. 1
        let mut sorted = qlens.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let (qmax, qs) = (sorted[0] as f64, sorted[1] as f64);
        let fires = policy.pick_target(&qlens).is_some();
        let should = qmax >= 1.0 && qmax > qs * (1.0 + tau);
        prop_assert!(
            fires == should,
            "qlens {qlens:?} τ={tau:.3}: fires={fires} eq1={should}"
        );
        if let Some(t) = policy.pick_target(&qlens) {
            prop_assert!(
                qlens[t] == sorted[0],
                "target {t} is not an argmax of {qlens:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_ceil_div() {
    forall("ceil_div is ceiling division", 200, |g| {
        let a = g.u64() % 1_000_000;
        let b = 1 + g.u64() % 1_000;
        let c = ceil_div(a, b);
        prop_assert!(c * b >= a, "{c}*{b} < {a}");
        prop_assert!(c == 0 || (c - 1) * b < a, "not minimal");
        Ok(())
    });
}

#[test]
fn prop_multiprobe_redistribute_is_empty_delta_zero_churn() {
    // ISSUE 2 satellite: multi-probe `redistribute` must produce an empty
    // RouteDelta (no token churn, no explicit key moves) — ownership only
    // shifts through the weight-aware probe choice, and stays a pure
    // function of the epoch (live-load changes between redistributions
    // must not move keys).
    forall("multi-probe redistribute = empty RouteDelta", 30, |g| {
        let nodes = g.usize_in(2, 10);
        let probes = 1 + g.usize_in(0, 7) as u32;
        let handle =
            RouterHandle::new(StrategySpec::MultiProbe { probes }.build_router(nodes, 8, None));
        for n in 0..nodes {
            handle.loads().set(n, g.usize_in(0, 200) as u64);
        }
        let keys: Vec<String> = (0..60).map(|_| g.string(16)).collect();
        let target = g.usize_in(0, nodes - 1);
        let delta = handle.redistribute(target);
        prop_assert!(delta.zero_token_churn(), "token churn: {delta:?}");
        prop_assert!(delta.keys_reassigned == 0, "explicit key moves: {delta:?}");
        prop_assert!(
            handle.snapshot().tokens().is_none(),
            "multi-probe grew a token table"
        );
        let after: Vec<usize> = keys.iter().map(|k| handle.route_key(k.as_bytes())).collect();
        // scramble the live loads: only a redistribute may shift ownership
        for n in 0..nodes {
            handle.loads().set(n, g.usize_in(0, 200) as u64);
        }
        for (k, &owner) in keys.iter().zip(&after) {
            prop_assert!(owner < nodes, "owner {owner} of '{k}' out of range");
            prop_assert!(
                handle.route_key(k.as_bytes()) == owner,
                "'{k}' moved without a redistribute (load-shift must be probe-time only)"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_token_ring_redistribute_moves_only_affected_keys() {
    // ISSUE 2 satellite: behind the Router trait, halving still moves only
    // keys owned by the target's removed tokens, and doubling only moves
    // keys onto nodes that gained tokens.
    forall("token-ring redistribute moves only affected keys", 30, |g| {
        let nodes = g.usize_in(2, 8);
        let tokens = 1u32 << g.usize_in(0, 4);
        let halving = g.bool();
        let op = if halving { RingOp::Halve } else { RingOp::DoubleOthers };
        let handle = RouterHandle::token_ring(Ring::new(nodes, tokens), op);
        let keys: Vec<String> = (0..80).map(|_| g.string(16)).collect();
        let before: Vec<usize> = keys.iter().map(|k| handle.route_key(k.as_bytes())).collect();
        let tokens_before: Vec<u32> = (0..nodes)
            .map(|n| handle.with_ring(|r| r.tokens_of(n)).unwrap())
            .collect();
        let target = g.usize_in(0, nodes - 1);
        let delta = handle.redistribute(target);
        if !delta.changed {
            return Ok(()); // halving exhausted / doubling saturated
        }
        let tokens_after: Vec<u32> = (0..nodes)
            .map(|n| handle.with_ring(|r| r.tokens_of(n)).unwrap())
            .collect();
        if halving {
            prop_assert!(
                delta.tokens_removed > 0 && delta.tokens_added == 0,
                "halving delta: {delta:?}"
            );
            for (k, &b) in keys.iter().zip(&before) {
                if b != target {
                    prop_assert!(
                        handle.route_key(k.as_bytes()) == b,
                        "'{k}' moved although node {b} lost no tokens"
                    );
                }
            }
        } else {
            prop_assert!(
                delta.tokens_added > 0 && delta.tokens_removed == 0,
                "doubling delta: {delta:?}"
            );
            for (k, &b) in keys.iter().zip(&before) {
                let now = handle.route_key(k.as_bytes());
                if now != b {
                    prop_assert!(
                        tokens_after[now] > tokens_before[now],
                        "'{k}' moved to node {now} which gained no tokens"
                    );
                }
            }
        }
        Ok(())
    });
}

/// Build a random router of a random family behind a capacity-bearing
/// handle, with some routed keys warming any sticky state.
fn random_elastic_handle(g: &mut Gen, keys: &[String]) -> RouterHandle {
    let nodes = g.usize_in(2, 6);
    let spec = match g.usize_in(0, 4) {
        0 => StrategySpec::Halving,
        1 => StrategySpec::Doubling,
        2 => StrategySpec::MultiProbe { probes: 1 + g.usize_in(0, 6) as u32 },
        3 => StrategySpec::Ptable { bits: g.usize_in(4, 8) as u32, replicas: 1 },
        _ => StrategySpec::TwoChoices,
    };
    let handle = RouterHandle::builder(spec.build_router(nodes, 8, None))
        .signal(&dpa::balancer::signal::SignalConfig::legacy())
        .capacity(nodes + 4)
        .build();
    for n in 0..nodes {
        handle.loads().set(n, g.usize_in(0, 50) as u64);
    }
    for k in keys {
        handle.route_key(k.as_bytes());
    }
    handle
}

#[test]
fn prop_retire_node_moves_only_the_retired_nodes_keys() {
    // ISSUE 5 satellite: for ALL router families, retire_node re-homes
    // exactly the keys the retired node owned — a key owned by any
    // surviving node never moves, and nothing routes to the retiree
    forall("retire_node moves only the retiree's keys", 40, |g| {
        let keys: Vec<String> = (0..80).map(|_| g.string(16)).collect();
        let handle = random_elastic_handle(g, &keys);
        let before: Vec<usize> = keys.iter().map(|k| handle.route_key(k.as_bytes())).collect();
        let victim = g.usize_in(0, handle.nodes() - 1);
        let delta = handle.retire_node(victim);
        if !delta.changed {
            return Ok(()); // last live node: refused, routing untouched
        }
        prop_assert!(delta.nodes_retired == 1, "delta {delta:?}");
        prop_assert!(!handle.is_live(victim), "victim still live");
        for (k, &b) in keys.iter().zip(&before) {
            let now = handle.route_key(k.as_bytes());
            prop_assert!(now != victim, "'{k}' still routes to retired node {victim}");
            if b != victim {
                prop_assert!(
                    now == b,
                    "'{k}' moved {b} -> {now} although node {b} survived ({})",
                    handle.name()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_add_node_never_moves_keys_between_survivors() {
    // ISSUE 5 satellite: for ALL router families, a join may only move
    // keys ONTO the new node — never between two pre-existing nodes
    forall("add_node moves keys only onto the joiner", 40, |g| {
        let keys: Vec<String> = (0..80).map(|_| g.string(16)).collect();
        let handle = random_elastic_handle(g, &keys);
        let before: Vec<usize> = keys.iter().map(|k| handle.route_key(k.as_bytes())).collect();
        let (id, delta) = handle.add_node().expect("capacity reserved");
        prop_assert!(delta.changed && delta.nodes_added == 1, "delta {delta:?}");
        prop_assert!(handle.is_live(id), "joiner not live");
        for (k, &b) in keys.iter().zip(&before) {
            let now = handle.route_key(k.as_bytes());
            if now != b {
                prop_assert!(
                    now == id,
                    "'{k}' moved {b} -> {now}, between survivors ({})",
                    handle.name()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ewma_signal_bounded_and_contracting() {
    // ISSUE 4 satellite: the decayed signal is (a) bounded by the
    // observed queue lengths — it can never report load nobody had —
    // and (b) monotone under decay: every update moves it (weakly)
    // toward the observed value, integer truncation included
    use dpa::balancer::signal::{FRAC_BITS, LoadSignal, SignalConfig};
    forall("EWMA bounded by observations and contracting", 60, |g| {
        let alpha = 0.05 + g.f64() * 0.95; // (0, 1]
        let cfg = SignalConfig {
            decay_alpha: alpha.min(1.0),
            hysteresis: g.f64(),
            min_gain: 0.0,
        };
        let s = LoadSignal::with_config(1, &cfg);
        let mut max_seen = 0u64;
        for _ in 0..g.usize_in(1, 40) {
            let q = g.usize_in(0, 10_000) as u64;
            max_seen = max_seen.max(q);
            let before = s.decayed(0);
            s.set(0, q);
            let after = s.decayed(0);
            let target = q << FRAC_BITS;
            prop_assert!(
                after <= max_seen << FRAC_BITS,
                "decayed {after} exceeds max observed {max_seen} (α={alpha})"
            );
            prop_assert!(
                after.abs_diff(target) <= before.abs_diff(target),
                "update moved away from the observation: |{after}-{target}| > \
                 |{before}-{target}| (α={alpha})"
            );
        }
        // monotone decay: observing silence strictly drains the signal
        let mut prev = s.decayed(0);
        for _ in 0..200 {
            s.set(0, 0);
            let d = s.decayed(0);
            prop_assert!(d <= prev, "decay increased the signal");
            prop_assert!(d < prev || prev == 0, "positive signal failed to decay");
            prev = d;
        }
        Ok(())
    });
}

#[test]
fn prop_migration_gain_guard_is_antisymmetric_on_skew() {
    // if moving a→b clears a positive gain guard, moving b→a must not:
    // a guard that admits both directions is exactly the ping-pong hazard
    use dpa::balancer::signal::{LoadSignal, SignalConfig};
    forall("min-gain guard admits at most one direction", 80, |g| {
        let cfg = SignalConfig {
            decay_alpha: 1.0,
            hysteresis: 0.0,
            min_gain: 0.01 + g.f64() * 0.9,
        };
        let s = LoadSignal::with_config(2, &cfg);
        s.set(0, g.usize_in(1, 1000) as u64);
        s.set(1, g.usize_in(1, 1000) as u64);
        prop_assert!(
            !(s.migration_gain_ok(0, 1) && s.migration_gain_ok(1, 0)),
            "guard admitted both directions for loads {:?}",
            s.to_vec()
        );
        Ok(())
    });
}

#[test]
fn prop_lockfree_two_choices_matches_locked_reference() {
    // ISSUE 6 tentpole: the lock-free sticky table + epoch-published
    // router must be *bit-identical* to the old `RwLock<TwoChoicesState>`
    // path. The reference model below IS that old path — a BTreeMap of
    // assignments mutated under exclusive access with the old selection
    // rules (first sight by decayed loads; redistribute re-homes every
    // other pinned key in ascending hash order behind the gain guard;
    // retire re-homes exactly the orphans under the shrunk membership) —
    // driven with the same op sequence across several epochs.
    use std::collections::BTreeMap;

    use dpa::hash::{two_choices_candidates_in, Loads};

    fn model_route(
        model: &mut BTreeMap<u32, u32>,
        live: &[u32],
        loads: &Loads,
        h: u32,
    ) -> usize {
        if let Some(&n) = model.get(&h) {
            return n as usize;
        }
        let (c1, c2) = two_choices_candidates_in(h, live);
        let pick = if loads.decayed(c2) < loads.decayed(c1) { c2 } else { c1 };
        model.insert(h, pick as u32);
        pick
    }

    forall("lock-free two-choices == locked reference model", 25, |g| {
        let nodes = g.usize_in(2, 6);
        let capacity = nodes + 3;
        let handle = RouterHandle::builder(StrategySpec::TwoChoices.build_router(nodes, 8, None))
            .signal(&dpa::balancer::signal::SignalConfig::legacy())
            .capacity(capacity)
            .build();
        let mut model: BTreeMap<u32, u32> = BTreeMap::new();
        let mut live: Vec<u32> = (0..nodes as u32).collect();
        let mut id_space = nodes;

        for step in 0..g.usize_in(10, 40) {
            match g.usize_in(0, 9) {
                // mostly: route a mix of fresh and already-seen hashes
                0..=5 => {
                    for _ in 0..12 {
                        let h = if g.bool() || model.is_empty() {
                            g.u32()
                        } else {
                            // revisit a sighted key: must be a sticky HIT
                            *model.keys().nth(g.usize_in(0, model.len() - 1)).unwrap()
                        };
                        let ours = handle.route_hash(h);
                        let reference = model_route(&mut model, &live, handle.loads(), h);
                        prop_assert!(
                            ours == reference,
                            "hash {h:#x} step {step}: lock-free {ours} != locked {reference}"
                        );
                    }
                }
                // shift the load signal (route-time input, no key moves)
                6 => {
                    for &n in &live {
                        handle.loads().set(n as usize, g.usize_in(0, 200) as u64);
                    }
                }
                // redistribute: every-other pinned key, ascending hashes
                7 => {
                    let target = live[g.usize_in(0, live.len() - 1)] as usize;
                    let delta = handle.redistribute(target);
                    let loads = handle.loads();
                    let pinned: Vec<u32> = model
                        .iter()
                        .filter(|&(_, &n)| n as usize == target)
                        .map(|(&k, _)| k)
                        .collect(); // BTreeMap iterates ascending
                    let mut moved = 0u64;
                    for (i, k) in pinned.iter().enumerate() {
                        if i % 2 != 0 {
                            continue;
                        }
                        let (c1, c2) = two_choices_candidates_in(*k, &live);
                        let alt = if c1 == target { c2 } else { c1 };
                        if alt == target || !loads.migration_gain_ok(target, alt) {
                            continue;
                        }
                        model.insert(*k, alt as u32);
                        moved += 1;
                    }
                    prop_assert!(
                        delta.keys_reassigned == moved,
                        "step {step}: redistribute moved {} keys, reference moved {moved}",
                        delta.keys_reassigned
                    );
                }
                // membership: scale up (until capacity), mirrored exactly
                8 => {
                    let ours = handle.add_node();
                    if id_space < capacity {
                        prop_assert!(
                            ours.map(|(id, _)| id) == Some(id_space),
                            "join id mismatch at {id_space}"
                        );
                        live.push(id_space as u32);
                        id_space += 1;
                    } else {
                        prop_assert!(ours.is_none(), "join beyond reserved capacity");
                    }
                }
                // membership: retire a random node, orphan rewrite mirrored
                _ => {
                    let victim = g.usize_in(0, id_space - 1);
                    let delta = handle.retire_node(victim);
                    let at = live.binary_search(&(victim as u32));
                    if live.len() <= 1 || at.is_err() {
                        prop_assert!(!delta.changed, "retire of {victim} should be refused");
                        continue;
                    }
                    live.remove(at.unwrap());
                    let loads = handle.loads();
                    let orphaned: Vec<u32> = model
                        .iter()
                        .filter(|&(_, &n)| n as usize == victim)
                        .map(|(&k, _)| k)
                        .collect();
                    for k in orphaned {
                        let (c1, c2) = two_choices_candidates_in(k, &live);
                        let n = if loads.decayed(c2) < loads.decayed(c1) { c2 } else { c1 };
                        model.insert(k, n as u32);
                    }
                    prop_assert!(delta.changed && delta.nodes_retired == 1, "{delta:?}");
                }
            }
        }
        // final sweep: every sighted key agrees, and so does a fresh batch
        for (&h, &n) in &model {
            prop_assert!(
                handle.route_hash(h) == n as usize,
                "final sweep: hash {h:#x} diverged"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_strategy_parse_display_roundtrip_every_family() {
    // ISSUE 10 satellite: `parse ∘ display == id` for every registry
    // family at random parameters — the Display form is the canonical
    // config spelling, so a spec that cannot survive the round trip
    // would be unreproducible from a report
    forall("parse(display(spec)) == spec for all families", 100, |g| {
        let spec = match g.usize_in(0, 6) {
            0 => StrategySpec::None,
            1 => StrategySpec::Halving,
            2 => StrategySpec::Doubling,
            3 => StrategySpec::MultiProbe { probes: 1 + g.usize_in(0, 15) as u32 },
            4 => StrategySpec::TwoChoices,
            5 => StrategySpec::SplitKey { d: g.usize_in(2, dpa::hash::MAX_SPLIT_D) as u32 },
            _ => StrategySpec::Ptable {
                bits: g.usize_in(1, 16) as u32,
                replicas: 1 + g.usize_in(0, 3) as u32,
            },
        };
        let shown = spec.to_string();
        let back: StrategySpec = shown
            .parse()
            .map_err(|e| format!("'{shown}' failed to re-parse: {e}"))?;
        prop_assert!(back == spec, "'{shown}' round-tripped to {back:?}, not {spec:?}");
        Ok(())
    });
}

#[test]
fn prop_ptable_rewrites_bounded_and_survivors_never_exchange() {
    // ISSUE 10 tentpole invariants, randomized: every membership rewrite
    // of the partition table (a) moves at most `ceil(2^B / n)` partitions
    // (n counting the joiner/victim) and (b) only moves partitions onto
    // the joiner or off the victim — two survivors never exchange a
    // partition during a membership change
    forall("ptable rewrites: bounded movement, survivor-stable", 30, |g| {
        let nodes = g.usize_in(2, 6);
        let bits = g.usize_in(4, 8) as u32;
        let partitions = 1usize << bits;
        let capacity = nodes + 4;
        let handle = RouterHandle::builder(
            StrategySpec::Ptable { bits, replicas: 1 }.build_router(nodes, 8, None),
        )
        .capacity(capacity)
        .build();
        let mut live: Vec<usize> = (0..nodes).collect();
        let mut id_space = nodes;
        for step in 0..g.usize_in(4, 12) {
            // warm the hit sketch so rewrites have a heat signal to prefer
            for _ in 0..20 {
                handle.route_hash(g.u32());
            }
            let before: Vec<u32> =
                handle.snapshot().partition_table().expect("ptable snapshot").0.to_vec();
            let adding = g.bool() && id_space < capacity;
            let (delta, bound, explain): (_, usize, Box<dyn Fn(usize) -> bool>) = if adding {
                let (id, delta) = handle.add_node().expect("capacity reserved");
                live.push(id);
                id_space += 1;
                let after: Vec<u32> =
                    handle.snapshot().partition_table().expect("ptable snapshot").0.to_vec();
                let bound = partitions.div_ceil(live.len());
                (delta, bound, {
                    let after = after.clone();
                    Box::new(move |p: usize| after[p] as usize == id)
                })
            } else {
                let victim = live[g.usize_in(0, live.len() - 1)];
                let delta = handle.retire_node(victim);
                if !delta.changed {
                    continue; // last live node: refused
                }
                let bound = partitions.div_ceil(live.len());
                live.retain(|&n| n != victim);
                let owned_before = before.clone();
                (delta, bound, Box::new(move |p: usize| owned_before[p] as usize == victim))
            };
            let after: Vec<u32> =
                handle.snapshot().partition_table().expect("ptable snapshot").0.to_vec();
            let changed: Vec<usize> =
                (0..partitions).filter(|&p| before[p] != after[p]).collect();
            prop_assert!(
                changed.len() <= bound,
                "step {step}: {} partitions moved, quota bound {bound}",
                changed.len()
            );
            prop_assert!(
                delta.partitions_moved as usize == changed.len(),
                "step {step}: delta says {} moved, table diff says {}",
                delta.partitions_moved,
                changed.len()
            );
            for &p in &changed {
                prop_assert!(
                    explain(p),
                    "step {step}: partition {p} moved {} -> {} between survivors",
                    before[p],
                    after[p]
                );
            }
            for &p in &changed {
                prop_assert!(
                    live.contains(&(after[p] as usize)),
                    "step {step}: partition {p} landed on dead node {}",
                    after[p]
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ptable_replicas_never_colocate_in_a_zone() {
    // ISSUE 10 satellite: with R-replica placement under a zone map, no
    // partition's placement ever puts two replicas in one failure domain
    // — as long as there are at least R distinct zones to walk
    use dpa::hash::{effective_zone, PartitionTableRouter, Router};
    forall("R replicas span R distinct zones", 30, |g| {
        let replicas = 2 + g.usize_in(0, 2) as u32;
        let zones_n = g.usize_in(1, 5);
        if (zones_n as u32) < replicas {
            // fewer domains than replicas: colocation is unavoidable by
            // pigeonhole — the placement walk degrades to distinct nodes,
            // which prop_ptable_rewrites covers; skip the zone claim
            return Ok(());
        }
        let nodes = g.usize_in(zones_n, 8);
        let bits = g.usize_in(3, 7) as u32;
        let mut r = PartitionTableRouter::new(nodes, bits, replicas);
        // nodes dealt round-robin across zones: every zone is populated
        let zone_of: Vec<u32> = (0..nodes).map(|n| (n % zones_n) as u32).collect();
        r.set_zones(&zone_of);
        // a couple of membership changes must preserve the placement rule
        let loads = dpa::hash::Loads::new(nodes);
        for _ in 0..g.usize_in(0, 2) {
            if g.bool() {
                r.add_node(r.nodes());
            } else {
                r.retire_node(g.usize_in(0, nodes - 1), &loads);
            }
        }
        // retires may have shrunk zone diversity below R; the walk then
        // legitimately degrades to distinct *nodes*, so the zone claim
        // only binds while the live set still spans ≥ R domains
        let mut live_zones: Vec<u32> = (0..r.nodes())
            .filter(|&n| r.is_live(n))
            .map(|n| effective_zone(&zone_of, n))
            .collect();
        live_zones.sort_unstable();
        live_zones.dedup();
        if (live_zones.len() as u32) < replicas {
            return Ok(());
        }
        for p in 0..r.partitions() {
            let placed = r.replicas_of(p);
            let mut zs: Vec<u32> =
                placed.iter().map(|&n| effective_zone(&zone_of, n)).collect();
            zs.sort_unstable();
            let before = zs.len();
            zs.dedup();
            prop_assert!(
                zs.len() == before,
                "partition {p}: placement {placed:?} co-locates two replicas in a zone \
                 (zones {zone_of:?})"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_pipeline_correct_on_random_workloads() {
    forall("pipeline == serial oracle on random input", 12, |g| {
        let n = g.usize_in(1, 300);
        let keyspace = g.usize_in(1, 40);
        let items: Vec<String> = (0..n)
            .map(|_| format!("k{}", g.usize_in(0, keyspace)))
            .collect();
        let strategy = *[Strategy::None, Strategy::Halving, Strategy::Doubling]
            .iter()
            .nth(g.usize_in(0, 2))
            .unwrap();
        let mut cfg = PipelineConfig::default();
        cfg.strategy = strategy;
        cfg.initial_tokens = Some(strategy.initial_tokens(8));
        cfg.seed = g.u64();
        cfg.max_rounds = 1 + g.usize_in(0, 3) as u32;
        let r = Pipeline::wordcount(cfg)
            .run(items.clone())
            .map_err(|e| format!("pipeline error: {e}"))?;
        r.check_conservation()?;
        let mut oracle = std::collections::HashMap::new();
        for i in &items {
            *oracle.entry(i.clone()).or_insert(0i64) += 1;
        }
        let mut expect: Vec<(String, i64)> = oracle.into_iter().collect();
        expect.sort();
        prop_assert!(r.result == expect, "result mismatch on {n} items");
        Ok(())
    });
}

#[test]
fn prop_slow_stall_plans_never_change_the_answer() {
    // ISSUE 9 satellite: any chaos plan made only of Slow / Stall /
    // DropReports events perturbs the *schedule*, never the data — the
    // merged output must equal the no-fault serial oracle for every
    // random plan, under either consistency mode.
    forall("slow/stall-only chaos plans preserve output", 10, |g| {
        let n = g.usize_in(50, 300);
        let keyspace = g.usize_in(5, 40);
        let items: Vec<String> =
            (0..n).map(|_| format!("k{}", g.usize_in(0, keyspace))).collect();
        let reducers = 4;
        let mut plan = Vec::new();
        for _ in 0..g.usize_in(1, 4) {
            let victim = g.usize_in(0, reducers - 1);
            let steps = g.usize_in(1, 30);
            plan.push(match g.usize_in(0, 2) {
                0 => format!("slow:{}@{victim}:{steps}", 2 + g.usize_in(0, 4)),
                1 => format!("stall:{}@{victim}:{steps}", 10 + g.usize_in(0, 80)),
                _ => format!("drop:{}@{victim}:{steps}", 1 + g.usize_in(0, 3)),
            });
        }
        let spec = plan.join(",");
        let mut cfg = PipelineConfig::default();
        cfg.strategy = Strategy::Doubling;
        cfg.initial_tokens = Some(8);
        cfg.mode = if g.bool() {
            ConsistencyMode::StateForward
        } else {
            ConsistencyMode::MergeAtEnd
        };
        cfg.seed = g.u64();
        cfg.max_rounds = 1 + g.usize_in(0, 2) as u32;
        cfg.chaos = Some(spec.clone());
        let r = Pipeline::wordcount(cfg)
            .run(items.clone())
            .map_err(|e| format!("pipeline error under plan '{spec}': {e}"))?;
        r.check_conservation()?;
        let mut oracle = std::collections::HashMap::new();
        for i in &items {
            *oracle.entry(i.clone()).or_insert(0i64) += 1;
        }
        let mut expect: Vec<(String, i64)> = oracle.into_iter().collect();
        expect.sort();
        prop_assert!(r.result == expect, "plan '{spec}' changed the answer");
        prop_assert!(r.recovery.kills == 0, "plan '{spec}' reported a kill");
        Ok(())
    });
}

#[test]
fn prop_workload_generators_conserve_length() {
    forall("generators emit requested item counts", 30, |g| {
        let n = g.usize_in(0, 500);
        let seed = g.u64();
        prop_assert!(
            dpa::workload::generators::uniform(n, 50, seed).len() == n,
            "uniform"
        );
        prop_assert!(
            dpa::workload::generators::zipf(n, 50, 1.1, seed).len() == n,
            "zipf"
        );
        Ok(())
    });
}

#[test]
fn prop_split_shard_merge_is_order_independent() {
    // ISSUE 8 tentpole: the associative merge contract. A stream sharded
    // d ways — each record folded on an arbitrary one of d candidate
    // homes, which is exactly what least-loaded-of-d degenerates to over
    // a run — must merge back, under ANY shard permutation, to the same
    // totals a single-homed reference reducer produces.
    use dpa::exec::builtin::WordCount;
    use dpa::exec::{merge_snapshots, MergeOp, Record, ReduceExecutor};

    forall("d-way shard fold == single-homed fold, any order", 30, |g| {
        let d = g.usize_in(2, 8);
        let keyspace = g.usize_in(1, 12);
        let n = g.usize_in(1, 300);
        let mut shards: Vec<WordCount> = (0..d).map(|_| WordCount::new()).collect();
        let mut single = WordCount::new();
        for _ in 0..n {
            let key = format!("k{}", g.usize_in(0, keyspace));
            shards[g.usize_in(0, d - 1)].reduce(Record::new(key.clone(), 1));
            single.reduce(Record::new(key, 1));
        }
        single.flush();
        let mut expect = single.snapshot();
        expect.sort();
        let mut partials: Vec<Vec<(String, i64)>> = shards
            .iter_mut()
            .map(|s| {
                s.flush();
                s.snapshot()
            })
            .collect();
        // shuffle the shard order: an associative+commutative fold must
        // not care which reducer's partial the coordinator sees first
        for i in (1..partials.len()).rev() {
            partials.swap(i, g.usize_in(0, i));
        }
        let mut merged = merge_snapshots(partials, MergeOp::Sum);
        merged.sort();
        prop_assert!(
            merged == expect,
            "shard merge diverged from the single-homed oracle (d={d}, n={n})"
        );
        Ok(())
    });
}

#[test]
fn prop_histogram_concurrent_equals_sequential_merge() {
    use dpa::metrics::Histogram;
    use std::sync::Arc;

    forall("N threads over disjoint value sets == sequential merge", 10, |g| {
        // disjoint per-thread value sets: thread t draws from its own
        // decade so any cross-thread increment lost or misrouted by the
        // relaxed hot path would show up as a bucket-sum mismatch
        let per_thread = g.usize_in(50, 400);
        let sets: Vec<Vec<u64>> = (0..4)
            .map(|t| {
                (0..per_thread)
                    .map(|_| (t as u64) * 1_000_000 + g.u64() % 900_000)
                    .collect()
            })
            .collect();

        let concurrent = Arc::new(Histogram::new());
        let mut joins = Vec::new();
        for set in &sets {
            let h = concurrent.clone();
            let set = set.clone();
            joins.push(std::thread::spawn(move || {
                for v in set {
                    h.record(v);
                }
            }));
        }
        for j in joins {
            j.join().map_err(|_| "recorder thread panicked".to_string())?;
        }

        let sequential = Histogram::new();
        for set in &sets {
            for &v in set {
                sequential.record(v);
            }
        }

        prop_assert!(
            concurrent.count() == sequential.count(),
            "total count diverged: {} vs {}",
            concurrent.count(),
            sequential.count()
        );
        let (cb, sb) = (concurrent.bucket_counts(), sequential.bucket_counts());
        prop_assert!(cb == sb, "per-bucket counts diverged from sequential merge");
        prop_assert!(
            concurrent.stats() == sequential.stats(),
            "percentile summary diverged"
        );
        Ok(())
    });
}
