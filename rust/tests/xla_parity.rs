//! Cross-layer parity: the rust-native data plane (murmur3, ring lookup,
//! wordcount) must agree bit-for-bit / count-for-count with the
//! AOT-compiled XLA programs executed through PJRT.
//!
//! Requires `make artifacts`. The whole file is one `#[test]` family over
//! a shared `Runtime` (compilation is the expensive part).

// experiment configs override one default knob at a time (see lib.rs)
#![allow(clippy::field_reassign_with_default)]

use std::sync::Arc;

use dpa::exec::builtin::{IdentityMap, WordCount};
use dpa::exec::xla::{xla_wordcount_factory, Interner, XlaWordCount};
use dpa::exec::{Record, ReduceExecutor};
use dpa::hash::{murmur3_x86_32, Ring, Strategy};
use dpa::pipeline::{Pipeline, PipelineConfig};
use dpa::runtime::programs::SharedRuntime;
use dpa::util::prng::Xoshiro256;

fn runtime() -> Arc<SharedRuntime> {
    SharedRuntime::load_default().expect("artifacts missing — run `make artifacts` first")
}

fn random_keys(n: usize, max_len: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Xoshiro256::new(seed);
    (0..n)
        .map(|_| {
            let len = rng.index(max_len + 1);
            (0..len).map(|_| rng.next_u64() as u8).collect()
        })
        .collect()
}

#[test]
fn murmur3_parity_rust_vs_xla() {
    let rt = runtime();
    // fixed vectors + random byte strings across every length 0..=32
    let mut keys: Vec<Vec<u8>> = vec![
        b"".to_vec(),
        b"a".to_vec(),
        b"abc".to_vec(),
        b"test".to_vec(),
        b"hello".to_vec(),
        b"Hello, world!".to_vec(),
    ];
    for len in 0..=32usize {
        keys.push((0..len).map(|i| (i * 7 + len) as u8).collect());
    }
    keys.extend(random_keys(700, 32, 0xA11CE));
    let refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
    let got = rt.hash_batch(&refs).unwrap();
    for (k, h) in keys.iter().zip(&got) {
        assert_eq!(*h, murmur3_x86_32(k), "key {k:?}");
    }
}

#[test]
fn route_parity_rust_vs_xla_across_repartitions() {
    let rt = runtime();
    let keys = random_keys(300, 24, 0xB0B);
    let refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();

    // exercise initial layouts AND post-redistribution rings
    let mut rings = vec![Ring::new(4, 8), Ring::new(4, 1), Ring::new(7, 3)];
    let mut r = Ring::new(4, 8);
    r.halve(2);
    r.halve(2);
    rings.push(r);
    let mut r = Ring::new(4, 1);
    r.double_others(0);
    r.double_others(1);
    rings.push(r);

    for ring in &rings {
        let routed = rt.route_batch(&refs, ring).unwrap();
        for (k, (h, owner)) in keys.iter().zip(&routed) {
            assert_eq!(*h, murmur3_x86_32(k));
            assert_eq!(
                *owner,
                ring.lookup(k),
                "key {k:?} disagrees on ring with {} tokens",
                ring.total_tokens()
            );
        }
        // the router-snapshot entry point must agree bit-for-bit with the
        // raw-ring path (same token table, same padding, same fallback)
        let handle =
            dpa::hash::RouterHandle::token_ring(ring.clone(), dpa::hash::RingOp::NoOp);
        let snap_routed = rt.route_batch_snapshot(&refs, &handle.snapshot()).unwrap();
        assert_eq!(routed, snap_routed, "snapshot path diverged from ring path");
    }
}

#[test]
fn compiled_route_parity_all_router_families_across_epochs() {
    // the tentpole contract: a RouteSnapshot from ANY router family
    // lowers to tensors and the compiled batch route agrees bit-for-bit
    // with the scalar Router::route — including post-redistribute epochs
    use dpa::hash::{RouterHandle, StrategySpec};
    let rt = runtime();
    let keys = random_keys(300, 24, 0xC0DE);
    let refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
    let specs = [
        StrategySpec::Halving,
        StrategySpec::Doubling,
        StrategySpec::MultiProbe { probes: 2 },
        StrategySpec::MultiProbe { probes: 4 },
        StrategySpec::TwoChoices,
        StrategySpec::Ptable { bits: 8, replicas: 1 },
        StrategySpec::Ptable { bits: 10, replicas: 2 },
    ];
    for spec in specs {
        let handle = RouterHandle::new(spec.build_router(4, 8, None));
        // warm the sticky table with a third of the keys; the rest hit
        // the compiled path cold (frozen-loads first-sight fallback)
        for &k in refs.iter().take(100) {
            handle.route_key(k);
        }
        for round in 0u64..3 {
            let epoch = handle.epoch();
            let snap = handle.snapshot();
            let routed = rt.route_batch_snapshot(&refs, &snap).unwrap();
            for (k, (h, owner)) in keys.iter().zip(&routed) {
                assert_eq!(*h, murmur3_x86_32(k), "{spec}");
                assert_eq!(
                    *owner,
                    handle.route_hash(*h),
                    "{spec} epoch {epoch} (round {round}) key {k:?}"
                );
            }
            // skew the loads onto one live owner and redistribute, so the
            // next round checks a genuinely different epoch
            let target = routed[0].1;
            for n in 0..4 {
                handle.loads().set(n, if n == target { 60 + round * 10 } else { 1 });
            }
            handle.redistribute(target);
        }
    }
}

#[test]
fn compiled_route_parity_with_decayed_signal_snapshots() {
    // ISSUE 4 tentpole: snapshots now freeze the EWMA-decayed loads
    // (fractional fixed point) and the hysteresis shed flags. The
    // compiled kernels must keep agreeing bit-for-bit with the scalar
    // routers when the frozen tensors carry those decayed values —
    // including flag sets with several reducers shed at once, which only
    // hysteresis (sticky flags) produces.
    use dpa::balancer::signal::SignalConfig;
    use dpa::hash::{RouterHandle, StrategySpec};
    let rt = runtime();
    let keys = random_keys(300, 24, 0xDECA7);
    let refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
    let signal = SignalConfig { decay_alpha: 0.3, hysteresis: 0.5, min_gain: 0.2 };
    for spec in [StrategySpec::MultiProbe { probes: 3 }, StrategySpec::TwoChoices] {
        let handle = RouterHandle::builder(spec.build_router(4, 8, None))
            .signal(&signal)
            .build();
        for &k in refs.iter().take(100) {
            handle.route_key(k);
        }
        for round in 0u64..4 {
            // drive a drifting load history through the EWMA so the
            // snapshot carries genuinely fractional decayed weights and
            // accumulated (sticky) hysteresis flags
            let hot = (round as usize) % 4;
            for step in 0..3u64 {
                for n in 0..4 {
                    handle.loads().set(n, if n == hot { 40 + step * 20 } else { 2 });
                }
            }
            handle.redistribute(hot);
            let snap = handle.snapshot();
            let routed = rt.route_batch_snapshot(&refs, &snap).unwrap();
            for (k, (h, owner)) in keys.iter().zip(&routed) {
                assert_eq!(*h, murmur3_x86_32(k), "{spec}");
                assert_eq!(
                    *owner,
                    handle.route_hash(*h),
                    "{spec} round {round} key {k:?}"
                );
            }
        }
    }
}

#[test]
fn compiled_route_parity_with_elastic_membership() {
    // the elastic acceptance contract: the compiled route programs must
    // agree bit-for-bit with the scalar routers across epochs whose NODE
    // COUNT varies — scale-up adds ids, scale-down leaves gaps in the id
    // space — for every compiled router family
    use dpa::balancer::signal::SignalConfig;
    use dpa::hash::{RouterHandle, StrategySpec};
    let rt = runtime();
    let keys = random_keys(300, 24, 0xE1A5);
    let refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
    let specs = [
        StrategySpec::Halving,
        StrategySpec::Doubling,
        StrategySpec::MultiProbe { probes: 3 },
        StrategySpec::TwoChoices,
        StrategySpec::Ptable { bits: 8, replicas: 2 },
    ];
    for spec in specs {
        let handle = RouterHandle::builder(spec.build_router(3, 8, None))
            .signal(&SignalConfig::legacy())
            .capacity(8)
            .build();
        // warm the sticky table so retires exercise the orphan rewrite
        for &k in refs.iter().take(100) {
            handle.route_key(k);
        }
        let check = |label: &str| {
            let snap = handle.snapshot();
            let routed = rt.route_batch_snapshot(&refs, &snap).unwrap();
            for (k, (h, owner)) in keys.iter().zip(&routed) {
                assert_eq!(*h, murmur3_x86_32(k), "{spec}");
                assert_eq!(
                    *owner,
                    handle.route_hash(*h),
                    "{spec} {label} (epoch {}, {} live of {} ids) key {k:?}",
                    handle.epoch(),
                    handle.live_count(),
                    handle.nodes()
                );
                assert!(handle.is_live(*owner), "{spec} {label}: routed to a dead node");
            }
        };
        check("initial 3 nodes");
        handle.add_node().expect("grow to 4");
        check("after scale-up to 4");
        handle.add_node().expect("grow to 5");
        check("after scale-up to 5");
        // retire a mid-range id: the id space keeps a gap at 1
        assert!(handle.retire_node(1).changed, "{spec}");
        check("after retiring id 1");
        // a redistribution epoch on the gapped membership
        for n in handle.live_nodes() {
            handle.loads().set(n, if n == 0 { 80 } else { 2 });
        }
        handle.redistribute(0);
        check("post-redistribute on gapped membership");
    }
}

#[test]
fn probe_snapshot_on_legacy_artifacts_errors_typed() {
    // artifacts written before route_probe/route_assign existed: loading
    // still works, a token snapshot still routes, and a probe snapshot
    // reports a typed UnsupportedSnapshot instead of panicking
    use dpa::hash::{RouterHandle, StrategySpec};
    let src = dpa::runtime::default_artifacts_dir().expect("artifacts missing");
    let tmp = std::env::temp_dir().join(format!("dpa-legacy-artifacts-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    for f in [
        "hash_only.hlo.txt",
        "route.hlo.txt",
        "reduce_count.hlo.txt",
        "reduce_count_raw.hlo.txt",
        "merge_state.hlo.txt",
        "manifest.json",
    ] {
        std::fs::copy(src.join(f), tmp.join(f)).unwrap();
    }
    let rt = SharedRuntime::load(&tmp).expect("legacy artifacts load");
    let keys: Vec<&[u8]> = vec![b"a".as_slice(), b"b".as_slice()];

    let ring = RouterHandle::token_ring(Ring::new(4, 8), dpa::hash::RingOp::NoOp);
    assert!(rt.route_batch_snapshot(&keys, &ring.snapshot()).is_ok());

    let probing =
        RouterHandle::new(StrategySpec::MultiProbe { probes: 3 }.build_router(4, 8, None));
    let err = rt.route_batch_snapshot(&keys, &probing.snapshot()).unwrap_err();
    match err.downcast_ref::<dpa::runtime::Error>() {
        Some(dpa::runtime::Error::UnsupportedSnapshot { router, .. }) => {
            assert_eq!(router, "multi-probe");
        }
        other => panic!("expected UnsupportedSnapshot, got {other:?}"),
    }

    // same for a partition-table snapshot: route_table.hlo.txt is absent
    let tabled = RouterHandle::new(
        StrategySpec::Ptable { bits: 8, replicas: 1 }.build_router(4, 8, None),
    );
    let err = rt.route_batch_snapshot(&keys, &tabled.snapshot()).unwrap_err();
    match err.downcast_ref::<dpa::runtime::Error>() {
        Some(dpa::runtime::Error::UnsupportedSnapshot { router, .. }) => {
            assert_eq!(router, "partition-table");
        }
        other => panic!("expected UnsupportedSnapshot, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn oversized_keys_fall_back_to_native() {
    let rt = runtime();
    let long = vec![b'x'; 100];
    let keys: Vec<&[u8]> = vec![b"short", long.as_slice()];
    let got = rt.hash_batch(&keys).unwrap();
    assert_eq!(got[0], murmur3_x86_32(b"short"));
    assert_eq!(got[1], murmur3_x86_32(&long));
}

#[test]
fn reduce_count_parity_with_hashmap() {
    let rt = runtime();
    let v = rt.manifest().v;
    let mut rng = Xoshiro256::new(42);
    let mut counts = vec![0u32; v];
    let mut oracle = std::collections::HashMap::new();
    for _ in 0..5 {
        let ids: Vec<i32> = (0..200).map(|_| rng.index(500) as i32).collect();
        for &id in &ids {
            *oracle.entry(id).or_insert(0u32) += 1;
        }
        counts = rt.reduce_counts(&counts, &ids).unwrap();
    }
    for (id, expect) in oracle {
        assert_eq!(counts[id as usize], expect, "id {id}");
    }
    assert_eq!(
        counts.iter().map(|&c| c as u64).sum::<u64>(),
        1000,
        "total records conserved"
    );
}

#[test]
fn merge_state_is_elementwise_add() {
    let rt = runtime();
    let v = rt.manifest().v;
    let mut rng = Xoshiro256::new(9);
    let a: Vec<u32> = (0..v).map(|_| rng.index(1000) as u32).collect();
    let b: Vec<u32> = (0..v).map(|_| rng.index(1000) as u32).collect();
    let merged = rt.merge_states(&a, &b).unwrap();
    for i in 0..v {
        assert_eq!(merged[i], a[i] + b[i]);
    }
}

#[test]
fn xla_wordcount_executor_matches_native() {
    let rt = runtime();
    let interner = Arc::new(Interner::new(rt.manifest().v));
    let mut xla = XlaWordCount::new(rt.clone(), interner);
    let mut native = WordCount::new();
    let mut rng = Xoshiro256::new(7);
    let pool = dpa::workload::generators::key_pool();
    for _ in 0..2000 {
        let key = pool[rng.index(100)].clone();
        xla.reduce(Record::new(key.clone(), 1));
        native.reduce(Record::new(key, 1));
    }
    assert_eq!(xla.snapshot(), native.snapshot());
    assert!(xla.dense_records > 0);
    assert_eq!(xla.spill_records, 0);
}

#[test]
fn xla_wordcount_extract_key_works() {
    let rt = runtime();
    let interner = Arc::new(Interner::new(rt.manifest().v));
    let mut xla = XlaWordCount::new(rt, interner);
    for _ in 0..5 {
        xla.reduce(Record::new("foo", 1));
    }
    xla.reduce(Record::new("bar", 1));
    assert_eq!(xla.extract_key("foo"), Some(5));
    assert_eq!(xla.extract_key("foo"), None);
    assert_eq!(xla.snapshot(), vec![("bar".to_string(), 1)]);
}

#[test]
fn xla_wordcount_spill_lane_for_nonunit_values() {
    let rt = runtime();
    let interner = Arc::new(Interner::new(rt.manifest().v));
    let mut xla = XlaWordCount::new(rt, interner);
    xla.reduce(Record::new("k", 10)); // non-unit -> spill
    xla.reduce(Record::new("k", 1)); // dense
    assert_eq!(xla.snapshot(), vec![("k".to_string(), 11)]);
    assert_eq!(xla.spill_records, 1);
    assert_eq!(xla.dense_records, 1);
}

#[test]
fn xla_dense_merge_runs_merge_program() {
    let rt = runtime();
    let interner = Arc::new(Interner::new(rt.manifest().v));
    let mut a = XlaWordCount::new(rt.clone(), interner.clone());
    let mut b = XlaWordCount::new(rt, interner);
    for _ in 0..3 {
        a.reduce(Record::new("foo", 1));
    }
    for _ in 0..4 {
        b.reduce(Record::new("foo", 1));
        b.reduce(Record::new("bar", 1));
    }
    let b_state = b.dense_state();
    a.merge_dense_from(&b_state).unwrap();
    let snap = a.snapshot();
    assert_eq!(
        snap,
        vec![("bar".to_string(), 4), ("foo".to_string(), 7)],
        "paper's state-merge example: counts add"
    );
}

#[test]
fn full_pipeline_on_xla_executors_sim_driver() {
    let rt = runtime();
    let factory = xla_wordcount_factory(rt);
    let mut cfg = PipelineConfig::default();
    cfg.strategy = Strategy::Doubling;
    let w = dpa::workload::paperwl::wl1();
    let pipeline = Pipeline::new(cfg, Arc::new(IdentityMap), factory);
    let report = pipeline.run(w.items.clone()).unwrap();
    // oracle
    let mut oracle = std::collections::HashMap::new();
    for i in &w.items {
        *oracle.entry(i.clone()).or_insert(0i64) += 1;
    }
    let mut expect: Vec<(String, i64)> = oracle.into_iter().collect();
    expect.sort();
    assert_eq!(report.result, expect);
    assert!(report.check_conservation().is_ok());
}

#[test]
fn full_pipeline_on_xla_executors_thread_driver() {
    let rt = runtime();
    let factory = xla_wordcount_factory(rt);
    let mut cfg = PipelineConfig::default();
    cfg.driver = dpa::pipeline::DriverKind::Threads;
    cfg.strategy = Strategy::Doubling;
    cfg.reduce_delay_us = 0; // XLA batch execution is the cost
    let items: Vec<String> = (0..600).map(|i| format!("w{}", i % 17)).collect();
    let pipeline = Pipeline::new(cfg, Arc::new(IdentityMap), factory);
    let report = pipeline.run(items).unwrap();
    assert_eq!(report.total_processed(), 600);
    assert_eq!(report.result.len(), 17);
    for (_, c) in &report.result {
        assert!(*c == 35 || *c == 36, "count {c}");
    }
}

#[test]
fn full_pipeline_compiled_route_path_every_router_family() {
    // mappers route whole tasks through the family's compiled route
    // program (Pipeline::with_route_runtime); results must stay exact for
    // every strategy, including the sticky-table write-back of two-choices
    let rt = runtime();
    for strategy in [
        Strategy::Halving,
        Strategy::Doubling,
        Strategy::MultiProbe { probes: 3 },
        Strategy::TwoChoices,
        Strategy::Ptable { bits: 8, replicas: 1 },
    ] {
        let factory = xla_wordcount_factory(rt.clone());
        let mut cfg = PipelineConfig::default();
        cfg.driver = dpa::pipeline::DriverKind::Threads;
        cfg.strategy = strategy;
        cfg.reduce_delay_us = 0;
        let items: Vec<String> = (0..600).map(|i| format!("w{}", i % 17)).collect();
        let pipeline =
            Pipeline::new(cfg, Arc::new(IdentityMap), factory).with_route_runtime(rt.clone());
        let report = pipeline.run(items).unwrap();
        assert_eq!(report.total_processed(), 600, "{strategy}");
        assert_eq!(report.result.len(), 17, "{strategy}");
        for (_, c) in &report.result {
            assert!(*c == 35 || *c == 36, "{strategy}: count {c}");
        }
    }
}
