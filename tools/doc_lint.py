#!/usr/bin/env python3
"""Documentation link lint: every relative link in the prose docs
resolves.

`cargo doc` already fails on broken *intra-rustdoc* links, but nothing
guarded the prose layer — `README.md` and `docs/*.md` cross-reference
each other (and files in the tree) heavily, and a renamed heading or
moved file silently strands readers. This lint closes that gap:

  R1  A relative link target (`[x](docs/ROUTING.md)`, `[x](../README.md)`)
      must exist on disk, resolved against the linking file's directory.
  R2  A fragment (`[x](ARCHITECTURE.md#the-router-layer)`, `[x](#local)`)
      must match a heading in the target file under GitHub's anchor
      rules: lowercase; drop everything but word characters, spaces and
      hyphens; spaces become hyphens; duplicate slugs get `-1`, `-2`, …
      suffixes. (`## §7 merge contracts` → `#7-merge-contracts`.)
  R3  Absolute URLs (`http:`, `https:`, `mailto:`) are out of scope —
      external rot is not something CI should gate merges on.

Fenced code blocks are skipped (ASCII diagrams and sample code may
contain `[…](…)`-shaped text that is not a link).

Scope: `README.md` and `docs/**/*.md`. Exit status: 0 clean, 1
violations (printed as `path:line: message`).

Usage: tools/doc_lint.py [--root REPO_ROOT]
"""

from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

RE_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
RE_HEADING = re.compile(r"^#{1,6}\s+(.*)$")
RE_FENCE = re.compile(r"^\s*(```|~~~)")
# Schemes whose targets live outside the repository (R3).
RE_EXTERNAL = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")


def anchor_slug(heading: str) -> str:
    """GitHub's heading→anchor rule (sans the duplicate suffix)."""
    text = heading.strip().lower()
    # inline code/emphasis markers vanish, their contents stay
    text = text.replace("`", "").replace("*", "")
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(text: str) -> set[str]:
    """Every anchor the rendered file exposes, duplicate-suffixed."""
    seen: dict[str, int] = {}
    anchors: set[str] = set()
    in_fence = False
    for line in text.splitlines():
        if RE_FENCE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = RE_HEADING.match(line)
        if not m:
            continue
        slug = anchor_slug(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def links_of(text: str) -> list[tuple[int, str]]:
    """(lineno, target) for every markdown link outside code fences."""
    out: list[tuple[int, str]] = []
    in_fence = False
    for i, line in enumerate(text.splitlines(), start=1):
        if RE_FENCE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in RE_LINK.finditer(line):
            out.append((i, m.group(1)))
    return out


def lint_file(path: Path, root: Path) -> list[tuple[str, int, str]]:
    rel = path.relative_to(root).as_posix()
    text = path.read_text(encoding="utf-8")
    violations: list[tuple[str, int, str]] = []
    for lineno, target in links_of(text):
        if RE_EXTERNAL.match(target):
            continue  # R3
        base, _, fragment = target.partition("#")
        dest = path if not base else (path.parent / base)
        if base and not dest.exists():
            violations.append(
                (rel, lineno,
                 f"broken link '{target}': '{base}' does not exist "
                 f"relative to {path.parent.relative_to(root).as_posix() or '.'}/"))
            continue
        if not fragment:
            continue
        if dest.is_dir() or dest.suffix.lower() != ".md":
            violations.append(
                (rel, lineno,
                 f"fragment link '{target}' into a non-markdown target — "
                 f"anchors only exist in rendered markdown"))
            continue
        if fragment not in anchors_of(dest.read_text(encoding="utf-8")):
            violations.append(
                (rel, lineno,
                 f"broken anchor '{target}': no heading in "
                 f"'{base or rel}' renders to '#{fragment}'"))
    return violations


def run(root: Path) -> list[tuple[str, int, str]]:
    docs = sorted((root / "docs").rglob("*.md")) if (root / "docs").is_dir() else []
    readme = root / "README.md"
    targets = ([readme] if readme.is_file() else []) + docs
    if not targets:
        raise SystemExit(f"doc_lint: no README.md or docs/*.md under {root}")
    violations: list[tuple[str, int, str]] = []
    for path in targets:
        violations.extend(lint_file(path, root))
    return violations


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--root",
        type=Path,
        default=Path(__file__).resolve().parent.parent,
        help="repo root (default: the checkout containing this script)",
    )
    args = ap.parse_args()
    violations = run(args.root)
    for rel, lineno, msg in violations:
        print(f"{rel}:{lineno}: {msg}")
    if violations:
        print(f"doc_lint: {len(violations)} violation(s)", file=sys.stderr)
        return 1
    print("doc_lint: clean — every relative link and anchor resolves")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
