#!/usr/bin/env python3
"""Memory-ordering lint: every atomic in the Rust crate goes through the
`crate::sync` shim.

The shim (`rust/src/sync/mod.rs`) is what lets the loom CI job compile
the whole crate with loom's permutation-exploring primitives under
`--cfg loom`. An atomic that bypasses it is invisible to the model
checker — the worst kind of concurrency bug surface: code that LOOKS
verified. This lint keeps the escape hatch shut:

  R1  `std::sync::atomic` may appear only in the shim itself or in an
      allowlisted file, and an allowlisted use must carry a
      `sync-lint allowlist` comment within the three lines above it
      explaining WHY it cannot go through the shim (e.g. `static`
      initializers — loom atomics are not const-constructible).
  R2  `loom::` may appear only in the shim. Product code must never
      name loom directly, or non-loom builds break and the cfg fence
      leaks.
  R3  A file that names `Ordering::` must import it from
      `crate::sync::atomic` (allowlisted files may import it from
      `std::sync::atomic` instead). This catches the subtle bypass
      `use std::sync::atomic as atomics` dodging R1's literal match.

Scope: `rust/src/**/*.rs`. Tests, benches and examples run only on real
threads (loom models live in `rust/tests/loom_models.rs` behind
`#![cfg(loom)]`), so std atomics are fine there.

Comment-only mentions are ignored (docs legitimately discuss orderings).
Exit status: 0 clean, 1 violations (printed as `path:line: message`).

Usage: tools/sync_lint.py [--root REPO_ROOT]
"""

from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

# Files (relative to rust/src) where the rules do not apply.
SHIM = "sync/mod.rs"

# Files (relative to rust/src) allowed to use std::sync::atomic directly,
# provided each use site carries a marker comment justifying it.
ALLOWLIST = {
    # `static INSTALLED: AtomicBool` — loom atomics have no const `new`.
    "util/logger.rs",
}

MARKER = "sync-lint allowlist"
# How many lines above a use site the marker comment may sit.
MARKER_WINDOW = 3

RE_STD_ATOMIC = re.compile(r"std::sync::atomic")
RE_LOOM = re.compile(r"\bloom::")
RE_ORDERING_USE = re.compile(r"\bOrdering::(Relaxed|Acquire|Release|AcqRel|SeqCst)\b")
RE_SHIM_IMPORT = re.compile(r"crate::sync::atomic")


def strip_comment(line: str) -> str:
    """Drop a trailing `//` comment. Crude (ignores string literals), but
    orderings never appear inside strings in this codebase, and cutting a
    URL out of a string can only *suppress* a match, never invent one."""
    idx = line.find("//")
    return line if idx < 0 else line[:idx]


def is_marked(lines: list[str], i: int) -> bool:
    """Is there a marker comment within MARKER_WINDOW lines above lines[i]?"""
    lo = max(0, i - MARKER_WINDOW)
    return any(MARKER in lines[j] for j in range(lo, i + 1))


def lint_file(path: Path, rel: str) -> list[tuple[str, int, str]]:
    if rel == SHIM:
        return []
    text = path.read_text(encoding="utf-8")
    lines = text.splitlines()
    violations: list[tuple[str, int, str]] = []
    allowlisted = rel in ALLOWLIST

    uses_ordering = False
    imports_shim = False
    imports_std_atomic = False

    for i, raw in enumerate(lines):
        line = strip_comment(raw)
        if RE_STD_ATOMIC.search(line):
            imports_std_atomic = True
            if not allowlisted:
                violations.append(
                    (rel, i + 1,
                     "raw `std::sync::atomic` outside the crate::sync shim — "
                     "import from `crate::sync::atomic` so loom models cover "
                     "this code, or add the file to the allowlist in "
                     "tools/sync_lint.py with a justifying comment"))
            elif not is_marked(lines, i):
                violations.append(
                    (rel, i + 1,
                     f"allowlisted file uses `std::sync::atomic` without a "
                     f"`{MARKER}` comment within {MARKER_WINDOW} lines "
                     f"explaining why the shim cannot be used"))
        if RE_LOOM.search(line):
            violations.append(
                (rel, i + 1,
                 "`loom::` outside the crate::sync shim — product code must "
                 "stay loom-agnostic; route through `crate::sync`"))
        if RE_ORDERING_USE.search(line):
            uses_ordering = True
        if RE_SHIM_IMPORT.search(line):
            imports_shim = True

    if uses_ordering and not imports_shim:
        if not (allowlisted and imports_std_atomic):
            violations.append(
                (rel, 1,
                 "file names `Ordering::…` but never imports "
                 "`crate::sync::atomic` — atomics here bypass the loom shim "
                 "(aliased import?)"))
    return violations


def run(root: Path) -> list[tuple[str, int, str]]:
    src = root / "rust" / "src"
    if not src.is_dir():
        raise SystemExit(f"sync_lint: no rust/src under {root}")
    violations: list[tuple[str, int, str]] = []
    for path in sorted(src.rglob("*.rs")):
        rel = path.relative_to(src).as_posix()
        violations.extend(lint_file(path, rel))
    return violations


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--root",
        type=Path,
        default=Path(__file__).resolve().parent.parent,
        help="repo root (default: the checkout containing this script)",
    )
    args = ap.parse_args()
    violations = run(args.root)
    for rel, lineno, msg in violations:
        print(f"rust/src/{rel}:{lineno}: {msg}")
    if violations:
        print(f"sync_lint: {len(violations)} violation(s)", file=sys.stderr)
        return 1
    print("sync_lint: clean — all atomics go through crate::sync")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
