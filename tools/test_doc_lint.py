#!/usr/bin/env python3
"""Self-test for tools/doc_lint.py — including the mandated negative
cases proving the lint FAILS on broken links and anchors."""

from __future__ import annotations

import sys
import tempfile
import unittest
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import doc_lint  # noqa: E402

REPO_ROOT = Path(__file__).resolve().parent.parent


class FixtureTree:
    """A throwaway repo root with a README + docs/ layout."""

    def __init__(self, tmp: str):
        self.root = Path(tmp)
        (self.root / "docs").mkdir(parents=True)

    def write(self, rel: str, content: str) -> None:
        p = self.root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(content, encoding="utf-8")


class DocLintTest(unittest.TestCase):
    def lint(self, build) -> list[tuple[str, int, str]]:
        with tempfile.TemporaryDirectory() as tmp:
            tree = FixtureTree(tmp)
            build(tree)
            return doc_lint.run(tree.root)

    # --- the anchor rule itself ------------------------------------------

    def test_github_slugging(self):
        self.assertEqual(doc_lint.anchor_slug("The Router layer"),
                         "the-router-layer")
        self.assertEqual(doc_lint.anchor_slug("§7 merge contracts"),
                         "7-merge-contracts")
        self.assertEqual(doc_lint.anchor_slug("Split-key in one page"),
                         "split-key-in-one-page")
        self.assertEqual(doc_lint.anchor_slug("`code` and *emphasis*"),
                         "code-and-emphasis")

    def test_duplicate_headings_get_suffixes(self):
        anchors = doc_lint.anchors_of("# A\n## Setup\n## Setup\n")
        self.assertEqual(anchors, {"a", "setup", "setup-1"})

    # --- clean trees pass -------------------------------------------------

    def test_clean_tree_passes(self):
        violations = self.lint(lambda t: (
            t.write("README.md",
                    "see [arch](docs/ARCH.md) and "
                    "[routers](docs/ARCH.md#the-router-layer) and "
                    "[web](https://example.com/x#frag)\n"),
            t.write("docs/ARCH.md",
                    "## The Router layer\nback to [readme](../README.md) "
                    "and [here](#the-router-layer)\n"),
        ))
        self.assertEqual(violations, [])

    def test_code_fences_are_skipped(self):
        violations = self.lint(lambda t: t.write(
            "README.md",
            "```\n[not a link](nowhere.md)\n## not a heading\n```\nok\n"))
        self.assertEqual(violations, [])

    # --- the negative tests: the lint MUST fail on these -----------------

    def test_broken_file_link_fails(self):
        violations = self.lint(lambda t: t.write(
            "README.md", "x\n\nsee [gone](docs/MISSING.md)\n"))
        self.assertEqual(len(violations), 1)
        rel, line, msg = violations[0]
        self.assertEqual((rel, line), ("README.md", 3))
        self.assertIn("does not exist", msg)

    def test_broken_anchor_fails(self):
        violations = self.lint(lambda t: (
            t.write("README.md", "see [x](docs/ARCH.md#no-such-heading)\n"),
            t.write("docs/ARCH.md", "## Real heading\n"),
        ))
        self.assertEqual(len(violations), 1)
        self.assertIn("broken anchor", violations[0][2])

    def test_broken_local_fragment_fails(self):
        violations = self.lint(lambda t: t.write(
            "README.md", "# Only\nsee [x](#absent)\n"))
        self.assertEqual(len(violations), 1)
        self.assertIn("#absent", violations[0][2])

    def test_fragment_into_non_markdown_fails(self):
        violations = self.lint(lambda t: (
            t.write("README.md", "see [x](docs/diagram.txt#part)\n"),
            t.write("docs/diagram.txt", "part\n"),
        ))
        self.assertEqual(len(violations), 1)
        self.assertIn("non-markdown", violations[0][2])

    def test_image_links_are_checked_too(self):
        violations = self.lint(lambda t: t.write(
            "README.md", "![shiny](docs/missing.png)\n"))
        self.assertEqual(len(violations), 1)

    # --- the real tree ----------------------------------------------------

    def test_actual_repo_is_clean(self):
        self.assertEqual(doc_lint.run(REPO_ROOT), [])


if __name__ == "__main__":
    unittest.main(verbosity=2)
