#!/usr/bin/env python3
"""Self-test for tools/sync_lint.py — including the mandated negative
cases proving the lint FAILS on raw atomic usage outside the shim."""

from __future__ import annotations

import sys
import tempfile
import unittest
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import sync_lint  # noqa: E402

REPO_ROOT = Path(__file__).resolve().parent.parent


class FixtureTree:
    """A throwaway repo root with a rust/src layout."""

    def __init__(self, tmp: str):
        self.root = Path(tmp)
        (self.root / "rust" / "src").mkdir(parents=True)

    def write(self, rel: str, content: str) -> None:
        p = self.root / "rust" / "src" / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(content, encoding="utf-8")


SHIM_SOURCE = """\
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicU64, Ordering};
    #[cfg(loom)]
    pub use loom::sync::atomic::{AtomicU64, Ordering};
}
"""

CLEAN_SOURCE = """\
use crate::sync::atomic::{AtomicU64, Ordering};
pub fn f(x: &AtomicU64) -> u64 {
    x.load(Ordering::Acquire)
}
"""


class SyncLintTest(unittest.TestCase):
    def lint(self, build) -> list[tuple[str, int, str]]:
        with tempfile.TemporaryDirectory() as tmp:
            tree = FixtureTree(tmp)
            tree.write("sync/mod.rs", SHIM_SOURCE)
            build(tree)
            return sync_lint.run(tree.root)

    def test_clean_tree_passes(self):
        violations = self.lint(lambda t: t.write("queue/mod.rs", CLEAN_SOURCE))
        self.assertEqual(violations, [])

    def test_shim_itself_may_use_std_and_loom(self):
        violations = self.lint(lambda t: None)
        self.assertEqual(violations, [])

    # --- the negative tests: the lint MUST fail on these -----------------

    def test_raw_std_atomic_fails(self):
        violations = self.lint(lambda t: t.write(
            "hash/router.rs",
            "use std::sync::atomic::{AtomicU64, Ordering};\n"))
        self.assertEqual(len(violations), 1)
        rel, line, msg = violations[0]
        self.assertEqual((rel, line), ("hash/router.rs", 1))
        self.assertIn("crate::sync", msg)

    def test_loom_outside_shim_fails(self):
        violations = self.lint(lambda t: t.write(
            "queue/mod.rs",
            "use loom::sync::atomic::AtomicUsize;\n"))
        self.assertEqual(len(violations), 1)
        self.assertIn("loom-agnostic", violations[0][2])

    def test_aliased_bypass_fails(self):
        # `use std::sync::atomic as x` dodged? R1 catches the literal path;
        # R3 catches orderings arriving through any other alias
        violations = self.lint(lambda t: t.write(
            "metrics/latency.rs",
            "use core::sync::atomic::Ordering;\n"
            "pub fn f() { let _ = Ordering::Relaxed; }\n"))
        self.assertEqual(len(violations), 1)
        self.assertIn("bypass", violations[0][2])

    def test_allowlisted_file_without_marker_fails(self):
        violations = self.lint(lambda t: t.write(
            "util/logger.rs",
            "use std::sync::atomic::{AtomicBool, Ordering};\n"))
        self.assertEqual(len(violations), 1)
        self.assertIn("sync-lint allowlist", violations[0][2])

    # --- allow / ignore paths --------------------------------------------

    def test_allowlisted_file_with_marker_passes(self):
        violations = self.lint(lambda t: t.write(
            "util/logger.rs",
            "// sync-lint allowlist: static latch, loom has no const new\n"
            "use std::sync::atomic::{AtomicBool, Ordering};\n"
            "pub fn f(b: &AtomicBool) -> bool { b.load(Ordering::SeqCst) }\n"))
        self.assertEqual(violations, [])

    def test_comment_mentions_are_ignored(self):
        violations = self.lint(lambda t: t.write(
            "hash/ring.rs",
            "// docs may discuss std::sync::atomic and loom:: freely,\n"
            "// and even Ordering::Release semantics, without tripping R3\n"
            "pub fn f() {}\n"))
        self.assertEqual(violations, [])

    def test_ordering_with_shim_import_passes(self):
        violations = self.lint(lambda t: t.write(
            "balancer/signal.rs", CLEAN_SOURCE))
        self.assertEqual(violations, [])

    # --- the real tree ----------------------------------------------------

    def test_actual_repo_is_clean(self):
        self.assertEqual(sync_lint.run(REPO_ROOT), [])


if __name__ == "__main__":
    unittest.main(verbosity=2)
